"""End-to-end behaviour tests for the HARMONY system.

The quickstart path: generate corpus → plan → build distributed index →
search → verify recall and the paper's headline behaviours at micro scale.
"""

import numpy as np
import pytest

from repro.config import HarmonyConfig
from repro.core import build_ivf, harmony_search, plan_search, preassign, search_oracle
from repro.data import brute_force_topk, make_dataset, make_queries, recall_at_k


@pytest.fixture(scope="module")
def system():
    # recall/plan-shape assertions hold at this scale; a larger corpus only
    # slows tier-1 down (heavier sweeps live in benchmarks/)
    ds = make_dataset(nb=8000, dim=128, n_components=32, spread=0.6, seed=11)
    cfg = HarmonyConfig(dim=128, nlist=64, nprobe=12, topk=10, kmeans_iters=6)
    index = build_ivf(ds.x, cfg)
    q_uniform = make_queries(ds, nq=64, skew=0.0, noise=0.2, seed=5)
    q_skewed = make_queries(ds, nq=64, skew=0.9, noise=0.2, seed=6)
    return ds, cfg, index, q_uniform, q_skewed


def test_end_to_end_recall(system):
    ds, cfg, index, q, _ = system
    decision = plan_search(index, 8, cfg)
    corpus = preassign(index, decision.plan)
    res = harmony_search(index, corpus, q)
    true_idx, _ = brute_force_topk(ds.x, q, cfg.topk)
    assert recall_at_k(res.ids, true_idx) > 0.85


def test_skew_shifts_plan_toward_dimension_blocks(system):
    """Under heavy skew the cost model should not pick pure-vector plans
    (the paper's core claim: hybrid/dimension wins under imbalance)."""
    ds, cfg, index, q_uniform, q_skewed = system
    from repro.core import assign_queries

    cfg_skewful = cfg.replace(alpha=50.0)
    probes_u = assign_queries(index, q_uniform)
    probes_s = assign_queries(index, q_skewed)
    d_uniform = plan_search(index, 8, cfg_skewful, probes_sample=probes_u)
    d_skewed = plan_search(index, 8, cfg_skewful, probes_sample=probes_s)
    assert d_skewed.plan.d_blocks >= d_uniform.plan.d_blocks


def test_modes_agree_on_results(system):
    ds, cfg, index, q, _ = system
    results = {}
    for mode, nodes in [("harmony", 8), ("vector", 8), ("dimension", 4)]:
        d = plan_search(index, nodes, cfg.replace(mode=mode))
        corpus = preassign(index, d.plan)
        results[mode] = harmony_search(index, corpus, q)
    base = results["harmony"].scores
    for mode, res in results.items():
        np.testing.assert_allclose(res.scores, base, rtol=1e-3, atol=1e-3)


def test_load_balance_improves_under_skew(system):
    """Load-aware assignment must reduce per-shard load spread vs round
    robin on skewed workloads (paper Fig. 7/9)."""
    ds, cfg, index, _, q_skewed = system
    from repro.core import assign_queries

    probes = assign_queries(index, q_skewed)
    d_bal = plan_search(index, 8, cfg.replace(mode="vector"), probes_sample=probes, balanced=True)
    d_rr = plan_search(index, 8, cfg.replace(mode="vector"), probes_sample=probes, balanced=False)
    c_bal = preassign(index, d_bal.plan)
    c_rr = preassign(index, d_rr.plan)
    r_bal = harmony_search(index, c_bal, q_skewed)
    r_rr = harmony_search(index, c_rr, q_skewed)
    imb = lambda r: np.std(r.stats["shard_pair_flops"]) / max(np.mean(r.stats["shard_pair_flops"]), 1)
    assert imb(r_bal) <= imb(r_rr) + 1e-9
