"""Fast docs checks in tier-1: required docs exist, every intra-repo
markdown link resolves, and the README quickstart block parses.

(Actually *executing* the quickstart lives in the CI docs job via
``tools/check_docs.py --quickstart`` — too slow for tier-1.)"""

import ast
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_required_docs_exist():
    for rel in ("README.md", "docs/ARCHITECTURE.md", "benchmarks/README.md",
                "ROADMAP.md", "CHANGES.md"):
        assert (REPO / rel).is_file(), f"missing {rel}"


def test_intra_repo_markdown_links_resolve():
    broken = _check_docs().check_links()
    assert not broken, f"broken markdown links: {broken}"


def test_readme_quickstart_parses():
    """The first fenced python block must at least be valid Python (CI
    executes it for real)."""
    cd = _check_docs()
    snippet = cd.extract_quickstart(REPO / "README.md")
    ast.parse(snippet)
    assert "build_ivf" in snippet      # it really is the quickstart


def test_architecture_doc_names_real_modules():
    """Every `src/...` path ARCHITECTURE.md mentions must exist — the
    paper→module map can't drift from the tree."""
    text = (REPO / "docs" / "ARCHITECTURE.md").read_text()
    import re

    for path in set(re.findall(r"`(src/[\w/]+\.py)`", text)):
        assert (REPO / path).is_file(), f"ARCHITECTURE.md names missing {path}"
    for path in set(re.findall(r"`(src/[\w/]+/)`", text)):
        assert (REPO / path).is_dir(), f"ARCHITECTURE.md names missing {path}"
