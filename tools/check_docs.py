"""Docs validation: intra-repo markdown links + the README quickstart.

Two checks, runnable together or separately (CI's docs job runs both):

* ``--links`` — every relative ``[text](target)`` link in the repo's
  markdown files must resolve to an existing file/directory (anchors are
  stripped; ``http(s)``/``mailto`` links are skipped).
* ``--quickstart`` — the first fenced ``python`` block in ``README.md``
  is extracted and executed with ``HARMONY_BENCH_TINY=1`` and
  ``PYTHONPATH=src`` — the quickstart cannot rot.

Usage (from the repo root):

    python tools/check_docs.py            # both checks
    python tools/check_docs.py --links
    python tools/check_docs.py --quickstart
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excluding images' inner brackets is unnecessary here;
# the target group stops at the first ')' which is fine for repo links
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)

SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", ".claude"}


def markdown_files():
    for path in sorted(REPO.rglob("*.md")):
        if not SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def check_links() -> list:
    """Return a list of ``(file, target)`` for links that don't resolve."""
    broken = []
    for md in markdown_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists():
                broken.append((str(md.relative_to(REPO)), target))
    return broken


def extract_quickstart(readme: Path) -> str:
    """All fenced python blocks, concatenated — the README's snippets are
    written to flow (the serving snippet reuses the quickstart's index),
    so the whole sequence must execute top to bottom."""
    blocks = FENCE_RE.findall(readme.read_text())
    if not blocks:
        raise SystemExit(f"no ```python block found in {readme}")
    return "\n\n".join(blocks)


def run_quickstart() -> int:
    snippet = extract_quickstart(REPO / "README.md")
    env = dict(os.environ)
    env["HARMONY_BENCH_TINY"] = "1"
    env["PYTHONPATH"] = (
        f"{REPO / 'src'}:{env['PYTHONPATH']}"
        if env.get("PYTHONPATH")
        else str(REPO / "src")
    )
    with tempfile.NamedTemporaryFile(
        "w", suffix="_quickstart.py", delete=False
    ) as f:
        f.write(snippet)
        path = f.name
    try:
        proc = subprocess.run(
            [sys.executable, path], env=env, cwd=REPO, timeout=600
        )
        return proc.returncode
    finally:
        os.unlink(path)


def main(argv) -> int:
    unknown = [a for a in argv if a not in ("--links", "--quickstart")]
    if unknown:
        # a typo must not silently skip every check and exit green
        print(f"unknown argument(s): {unknown}; "
              "use --links and/or --quickstart (default: both)")
        return 2
    do_links = "--links" in argv or len(argv) == 0
    do_quickstart = "--quickstart" in argv or len(argv) == 0
    rc = 0
    if do_links:
        broken = check_links()
        if broken:
            print("BROKEN markdown links:")
            for where, target in broken:
                print(f"  {where}: {target}")
            rc = 1
        else:
            n = sum(1 for _ in markdown_files())
            print(f"links OK across {n} markdown files")
    if do_quickstart:
        print("running README quickstart (HARMONY_BENCH_TINY=1)...")
        q_rc = run_quickstart()
        if q_rc != 0:
            print(f"README quickstart FAILED (exit {q_rc})")
            rc = 1
        else:
            print("README quickstart OK")
    return rc


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
